"""Roofline analysis from dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json and derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s)

All three in seconds, using scan-calibrated per-device totals
(flops_corrected etc.; EXPERIMENTS.md §Dry-run explains the calibration).
Also reports MODEL_FLOPS = 6*N*D (train) / 2*N*D (serve), the useful-flops
ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and the roofline
fraction  MODEL_FLOPS/(chips*peak) / max(term)  — the score §Perf
hillclimbs.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

PEAK = 197e12       # bf16 FLOP/s per chip (v5e)
HBM = 819e9         # bytes/s per chip
LINK = 50e9         # bytes/s per chip ICI link

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(tag: str = ""):
    recs = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped"):
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def terms(rec: dict) -> dict:
    cal = rec.get("calib", {})
    # two flop sources, each undercounting differently on the CPU backend
    # (cost_analysis drops fused dots; the dot parser ignores non-dot ops):
    # take the max — see EXPERIMENTS.md §Dry-run methodology.
    flops = max(
        cal.get("flops_corrected", rec.get("hlo_flops_per_device", 0.0)),
        cal.get("dot_flops_corrected",
                rec.get("hlo_dot_flops_per_device", 0.0)))
    bytes_ = cal.get("bytes_corrected", rec.get("hlo_bytes_per_device", 0.0))
    wire = cal.get("wire_corrected_total",
                   rec.get("collective_total_per_device", 0.0))
    devices = rec["devices"]
    t_comp = flops / PEAK
    t_mem = bytes_ / HBM
    t_coll = wire / LINK
    t_max = max(t_comp, t_mem, t_coll, 1e-30)
    dominant = {t_comp: "compute", t_mem: "memory",
                t_coll: "collective"}[t_max]
    model_flops = rec.get("model_flops", 0.0)
    hlo_total = flops * devices
    useful = model_flops / hlo_total if hlo_total else 0.0
    t_ideal = model_flops / (devices * PEAK)
    frac = t_ideal / t_max if t_max > 0 else 0.0
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "devices": devices, "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": model_flops, "hlo_flops_total": hlo_total,
            "useful_flops_ratio": useful, "roofline_fraction": frac,
            "temp_gib": rec.get("memory", {}).get(
                "temp_size_in_bytes", 0) / 2**30,
            "args_gib": rec.get("memory", {}).get(
                "argument_size_in_bytes", 0) / 2**30}


def table(tag: str = "", mesh: str = "single", out=sys.stdout):
    rows = [terms(r) for r in load(tag) if r["ok"] and r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"{'arch':22s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dom':>6s} {'useful':>7s} {'roofl%':>7s} "
           f"{'temp GiB':>9s}")
    print(hdr, file=out)
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
              f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
              f"{r['dominant'][:6]:>6s} {r['useful_flops_ratio']:7.3f} "
              f"{100*r['roofline_fraction']:7.2f} {r['temp_gib']:9.2f}",
              file=out)
    return rows


def kernels_table(json_path=None, out=sys.stdout):
    """Kernel-engine roofline rows from kernels_bench's BENCH_kernels.json
    (benchmarks/kernels_bench.py --json): X passes per iteration, bytes
    moved and the predicted memory/compute-bound time per variant — the
    K-Means analogue of the dry-run table above, analytic because the
    fused Pallas kernels only execute natively on a TPU."""
    path = Path(json_path) if json_path else \
        Path(__file__).resolve().parents[1] / "BENCH_kernels.json"
    if not path.exists():
        return []
    recs = json.loads(path.read_text()).get("records", [])
    print(f"\n=== kernel engine ({path.name}) ===", file=out)
    print(f"{'variant':24s} {'n':>8s} {'d':>5s} {'k':>7s} {'Xpass':>6s} "
          f"{'bytes':>10s} {'ai':>7s} {'pred_us':>8s} {'bound':>7s} "
          f"{'skip':>6s} {'phase':>10s}", file=out)
    for r in recs:
        pred = max(r["t_mem_us"], r["t_comp_us"])
        # pre-v3 records carry no tile-skip columns; print them as absent
        skip = r.get("skipped_tile_frac")
        skip_s = "-" if skip is None else f"{skip:.3f}"
        phase_s = r.get("phase") or "-"
        print(f"{r['variant']:24s} {r['n']:8d} {r['d']:5d} {r['k']:7d} "
              f"{r['x_passes_per_iter']:6g} {r['bytes_per_iter']:10.2e} "
              f"{r['ai']:7.1f} {pred:8.1f} {r['bound']:>7s} "
              f"{skip_s:>6s} {phase_s:>10s}", file=out)
    return recs


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    for mesh in ("single", "multi"):
        print(f"\n=== mesh: {mesh} ({'512' if mesh == 'multi' else '256'} "
              f"chips) tag={tag or 'baseline'} ===")
        rows = table(tag, mesh)
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            coll = max(rows, key=lambda r: r["t_collective_s"] /
                       max(r["t_compute_s"], 1e-30))
            print(f"\nworst roofline fraction: {worst['arch']} "
                  f"{worst['shape']} ({100*worst['roofline_fraction']:.2f}%)")
            print(f"most collective-bound:  {coll['arch']} {coll['shape']} "
                  f"(coll/comp = "
                  f"{coll['t_collective_s']/max(coll['t_compute_s'],1e-30):.2f})")
    kernels_table()


if __name__ == "__main__":
    main()
