"""Batched-engine sweep: a Table-2-style (dataset-variant x K) grid run as
one batched device program per K, plus the headline batched-vs-sequential
multi-restart comparison.

    PYTHONPATH=src python -m benchmarks.batched_sweep [--restarts 8]

Two measurements:

1. restarts — R K-Means++ restarts of one dataset, solved (a) by the old
   sequential Python loop (R jit dispatches of `aa_kmeans`) and (b) by ONE
   `aa_kmeans_batched` program with on-device best-of-R selection.  Both
   warm.  This is exactly what `AAKMeans(n_init=R).fit` now executes, and
   the paper's robustness protocol (120 instances = datasets x K x
   seedings) is this shape at scale.
2. grid — G same-shape dataset variants x each K in --ks, each (variant, K)
   cell seeded independently; for every K the G problems solve as one
   batched program over the problem axis ((R, N, d) mode).  K changes the
   centroid shape, so each K is its own program — shapes, not Python
   loops, delimit the batch.

The sweep prints per-case wall times and a final ``batched_speedup``
CSV row (sequential_time / batched_time for the restart case).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed
from repro.core.backends import backend_names
from repro.core.init_schemes import batched_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans, aa_kmeans_batched,
                               select_best)
from repro.data.synthetic import make_blobs


def _wall(fn, *args, reps: int = 5):
    """Min-of-reps wall time (see common.timed's reduce note)."""
    return timed(fn, *args, reps=reps, reduce=min)


def restart_comparison(n=4096, d=8, k=10, restarts=8, seed=0,
                       backend="dense", max_iter=500, verbose=True):
    """Batched best-of-R vs the sequential restart loop, both warm."""
    x = jnp.asarray(make_blobs(n, d, k, seed=seed, spread=1.5))
    keys = jax.random.split(jax.random.PRNGKey(seed), restarts)
    c0s = batched_init("kmeans++", keys, x, k)
    cfg = KMeansConfig(k=k, max_iter=max_iter)

    seq_one = jax.jit(lambda a, b: aa_kmeans(a, b, cfg, backend=backend))

    def sequential(xx, cc):
        best = None
        for r in range(restarts):
            res = seq_one(xx, cc[r])
            if best is None or float(res.energy) < float(best.energy):
                best = res
        return best

    batched = jax.jit(lambda a, b: select_best(
        aa_kmeans_batched(a, b, cfg, backend=backend)))

    # interleave the two arms so load drift hits both equally
    res_s, t_seq = _wall(sequential, x, c0s)
    res_b, t_bat = _wall(batched, x, c0s)
    _, t_seq2 = _wall(sequential, x, c0s)
    _, t_bat2 = _wall(batched, x, c0s)
    t_seq, t_bat = min(t_seq, t_seq2), min(t_bat, t_bat2)
    # quality bound, not exact equality: a last-ulp accept flip near
    # convergence may land the winning restart on a neighbouring optimum
    # (DESIGN.md §Batching) — 1% matches the test suite's contract
    e_s, e_b = float(res_s.energy), float(res_b.energy)
    assert abs(e_s - e_b) <= 0.01 * e_s, (e_s, e_b)
    if verbose:
        print(f"restarts R={restarts} N={n} d={d} K={k} [{backend}] | "
              f"sequential {t_seq*1e3:8.1f}ms  batched {t_bat*1e3:8.1f}ms  "
              f"speedup {t_seq/t_bat:4.2f}x  "
              f"best-E match {float(res_b.energy):.2f}", flush=True)
    return {"t_seq": t_seq, "t_batched": t_bat,
            "speedup": t_seq / t_bat, "energy": float(res_b.energy)}


def grid_sweep(n=2048, d=8, n_variants=6, ks=(5, 10, 20), seed=0,
               backend="dense", max_iter=300, verbose=True):
    """(dataset-variant x K) grid, one batched program per K."""
    xs = jnp.stack([jnp.asarray(make_blobs(n, d, 12, seed=seed + 100 + g,
                                           spread=1.0 + 0.4 * g))
                    for g in range(n_variants)])          # (G, N, d)
    rows = []
    for k in ks:
        keys = jax.random.split(jax.random.PRNGKey(seed + k), n_variants)
        c0s = batched_init("kmeans++", keys, xs, k)
        cfg = KMeansConfig(k=k, max_iter=max_iter)
        fn = jax.jit(lambda a, b, cfg=cfg: aa_kmeans_batched(a, b, cfg,
                                                             backend=backend))
        res, t = _wall(fn, xs, c0s)
        mses = [float(res.energy[g]) / n for g in range(n_variants)]
        rows.append({"k": k, "time_s": t,
                     "n_iter": [int(v) for v in res.n_iter],
                     "mse": mses})
        if verbose:
            print(f"grid K={k:3d} G={n_variants} [{backend}] | one program "
                  f"{t*1e3:8.1f}ms | iters {rows[-1]['n_iter']} | "
                  f"mean MSE {np.mean(mses):.4f}", flush=True)
    return rows


def main(restarts=8, backend="dense", verbose=True):
    rc = restart_comparison(restarts=restarts, backend=backend,
                            verbose=verbose)
    grid = grid_sweep(backend=backend, verbose=verbose)
    print(csv_row("batched_sweep.sequential", rc["t_seq"] * 1e6))
    print(csv_row("batched_sweep.batched", rc["t_batched"] * 1e6,
                  f"speedup={rc['speedup']:.2f}x"))
    print(csv_row("batched_sweep.grid",
                  sum(r["time_s"] for r in grid) * 1e6,
                  f"cells={sum(len(r['n_iter']) for r in grid)}"))
    return {"restarts": rc, "grid": grid}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--restarts", type=int, default=8)
    ap.add_argument("--backend", default="dense",
                    choices=sorted(backend_names()))
    args = ap.parse_args()
    main(restarts=args.restarts, backend=args.backend)
