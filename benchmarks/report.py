"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report [tag] > artifacts/roofline.md
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.roofline import ARTIFACTS, load, terms


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b/2**30:.2f} GiB"
    return f"{b/2**20:.1f} MiB"


def dryrun_table(tag=""):
    recs = load(tag)
    lines = ["| arch | shape | mesh | compile | args/dev | temp/dev | "
             "HLO GFLOP/dev | coll MB/dev (wire) | top collectives |",
             "|---|---|---|---|---|---|---|---|---|"]
    skips = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(p.read_text())
        if (r.get("tag") or "") != tag:
            continue
        if r.get("skipped"):
            skips.append(r)
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL: {r.get('error','')[:60]} | | | | | |")
            continue
        cal = r.get("calib", {})
        mem = r.get("memory", {})
        coll = cal.get("wire_corrected",
                       r.get("collective_wire_bytes_per_device", {}))
        top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
        top_s = ", ".join(f"{k}:{fmt_bytes(v)}" for k, v in top if v > 0)
        flops = cal.get("flops_corrected", r.get("hlo_flops_per_device", 0))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('time_compile_s', 0):.0f}s | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes', 0))} | "
            f"{flops/1e9:.1f} | "
            f"{cal.get('wire_corrected_total', 0)/2**20:.1f} | {top_s} |")
    return "\n".join(lines), skips


def roofline_table(tag="", mesh="single"):
    rows = [terms(r) for r in load(tag)
            if r.get("ok") and not r.get("skipped") and r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.3f} | "
            f"{100*r['roofline_fraction']:.2f}% |")
    return "\n".join(lines), rows


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    dr, skips = dryrun_table(tag)
    print("## Dry-run table (tag:", tag or "baseline", ")\n")
    print(dr)
    print("\nSkipped cells (per assignment):")
    for s in skips:
        print(f"* {s['arch']} {s['shape']} {s['mesh']}: {s['skip_reason']}")
    for mesh in ("single", "multi"):
        rt, rows = roofline_table(tag, mesh)
        print(f"\n## Roofline ({mesh}-pod)\n")
        print(rt)


if __name__ == "__main__":
    main()
