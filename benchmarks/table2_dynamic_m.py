"""Paper Table 2: fixed-m vs dynamic-m Anderson acceleration.

For each dataset (synthetic stand-ins at --scale of Table 1 sizes, K=10,
K-Means++ seeding — the paper's Table 2 protocol): run AA-KMeans with
fixed m in {2, 5} and dynamic m initialised at {2, 5}; report a/b
iterations, wall time (jit, warm), and MSE.

The paper's claim validated here: dynamic m reduces time/iterations vs the
same fixed m on most datasets (Table 2; Sec. 3.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timed, traced_run
from repro.core.anderson import AAConfig
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.data.synthetic import DATASETS, make_dataset

DEFAULT_DATASETS = list(DATASETS)


def run_one(x, c0, k, m0, dynamic, backend="dense"):
    cfg = KMeansConfig(k=k, max_iter=1000,
                       aa=AAConfig(m0=m0, dynamic_m=dynamic))
    fn = jax.jit(lambda a, b: aa_kmeans(a, b, cfg, backend=backend))
    res, dt = timed(fn, x, c0)
    out = {"a": int(res.n_accepted), "b": int(res.n_iter),
           "time_s": dt, "mse": float(res.energy) / x.shape[0]}
    if dynamic:
        # the window trajectory the paper discusses alongside Table 2;
        # stats only (the headline time above stays the jitted whole-loop
        # run), so skip the warm-up's extra solve
        tr = traced_run(x, c0, cfg, backend=backend, warmup=False)
        out["mean_m"] = (sum(tr.m_values) / len(tr.m_values)
                         if tr.m_values else float(m0))
        out["max_m"] = max(tr.m_values, default=m0)
    return out


def run(scale=0.05, k=10, datasets=None, seed=0, verbose=True,
        backend="dense"):
    rows = []
    wins = {2: 0, 5: 0}
    total = {2: 0, 5: 0}
    for name in (datasets or DEFAULT_DATASETS):
        x = jnp.asarray(make_dataset(name, scale=scale, seed=seed))
        c0 = kmeanspp_init(jax.random.PRNGKey(seed), x, k)
        line = {"dataset": name, "n": x.shape[0]}
        for m0 in (2, 5):
            fx = run_one(x, c0, k, m0, dynamic=False, backend=backend)
            dy = run_one(x, c0, k, m0, dynamic=True, backend=backend)
            line[f"fixed_m{m0}"] = fx
            line[f"dyn_m{m0}"] = dy
            total[m0] += 1
            if dy["time_s"] <= fx["time_s"]:
                wins[m0] += 1
        rows.append(line)
        if verbose:
            f2, d2 = line["fixed_m2"], line["dyn_m2"]
            f5, d5 = line["fixed_m5"], line["dyn_m5"]
            print(f"{name:20s} N={line['n']:7d} | m=2 fixed {f2['a']}/{f2['b']} "
                  f"{f2['time_s']*1e3:7.1f}ms vs dyn {d2['a']}/{d2['b']} "
                  f"{d2['time_s']*1e3:7.1f}ms (m~{d2['mean_m']:.1f}) | "
                  f"m=5 fixed {f5['a']}/{f5['b']} "
                  f"{f5['time_s']*1e3:7.1f}ms vs dyn {d5['a']}/{d5['b']} "
                  f"{d5['time_s']*1e3:7.1f}ms (m~{d5['mean_m']:.1f})",
                  flush=True)
    summary = {"wins_dynamic_m2": wins[2], "wins_dynamic_m5": wins[5],
               "total": total[2], "rows": rows}
    return summary


def main(scale=0.05, backend="dense"):
    s = run(scale=scale, backend=backend)
    mean_t = lambda key: sum(r[key]["time_s"] for r in s["rows"]) / len(s["rows"])
    print(csv_row("table2.fixed_m2", mean_t("fixed_m2") * 1e6,
                  f"wins_dyn={s['wins_dynamic_m2']}/{s['total']}"))
    print(csv_row("table2.dynamic_m2", mean_t("dyn_m2") * 1e6))
    print(csv_row("table2.fixed_m5", mean_t("fixed_m5") * 1e6,
                  f"wins_dyn={s['wins_dynamic_m5']}/{s['total']}"))
    print(csv_row("table2.dynamic_m5", mean_t("dyn_m5") * 1e6))
    return s


if __name__ == "__main__":
    main()
