"""Benchmark orchestrator: one function per paper table + kernel/roofline
reports.  Prints ``name,us_per_call,derived`` CSV (plus human-readable
tables above each block).

    PYTHONPATH=src python -m benchmarks.run [--scale 0.05] [--fast]
"""

from __future__ import annotations

import argparse
import traceback


def main() -> None:
    from repro.core.backends import backend_names

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05,
                    help="fraction of Table-1 dataset sizes (1.0 = paper)")
    ap.add_argument("--fast", action="store_true",
                    help="first 6 datasets only")
    ap.add_argument("--backend", default="dense",
                    choices=sorted(backend_names()),
                    help="solver engine for the table runs "
                         "(repro.core.backends registry)")
    ap.add_argument("--checkpoint-every", type=int, default=10, metavar="S",
                    help="segment length for the persistence-overhead "
                         "block (benchmarks/checkpoint_bench.py)")
    args = ap.parse_args()

    from benchmarks import kernels_bench, roofline, table2_dynamic_m, \
        table3_vs_lloyd
    from repro.data.synthetic import DATASETS

    datasets = list(DATASETS)[:6] if args.fast else None

    print("# === Table 2: fixed vs dynamic m ===", flush=True)
    try:
        s2 = table2_dynamic_m.run(scale=args.scale, datasets=datasets,
                                  backend=args.backend)
        n = s2["total"]
        mean = lambda key: sum(r[key]["time_s"] for r in s2["rows"]) / n
        print(f"table2.fixed_m2,{mean('fixed_m2')*1e6:.1f},")
        print(f"table2.dynamic_m2,{mean('dyn_m2')*1e6:.1f},"
              f"wins={s2['wins_dynamic_m2']}/{n}")
        print(f"table2.fixed_m5,{mean('fixed_m5')*1e6:.1f},")
        print(f"table2.dynamic_m5,{mean('dyn_m5')*1e6:.1f},"
              f"wins={s2['wins_dynamic_m5']}/{n}")
    except Exception:
        traceback.print_exc()

    print("# === Table 3: AA-KMeans vs Lloyd ===", flush=True)
    try:
        s3 = table3_vs_lloyd.run(scale=args.scale, datasets=datasets,
                                 backend=args.backend)
        mean_l = sum(c["lloyd_time_s"] for c in s3["cases"]) / s3["total"]
        mean_a = sum(c["aa_time_s"] for c in s3["cases"]) / s3["total"]
        print(f"table3.lloyd,{mean_l*1e6:.1f},")
        print(f"table3.aa,{mean_a*1e6:.1f},"
              f"wins={s3['wins']}/{s3['total']};"
              f"iter_wins={s3['iter_wins']}/{s3['total']};"
              f"mean_time_decrease={s3['mean_time_decrease']:.1%};"
              f"mse_parity={s3['mse_parity']}/{s3['total']}")
    except Exception:
        traceback.print_exc()

    print("# === Batched engine: multi-restart + grid sweep ===", flush=True)
    try:
        from benchmarks import batched_sweep
        batched_sweep.main(backend=args.backend)
    except Exception:
        traceback.print_exc()

    print("# === Checkpoint segmentation overhead ===", flush=True)
    try:
        from benchmarks import checkpoint_bench
        checkpoint_bench.main(
            ["--json", "--checkpoint-every", str(args.checkpoint_every)]
            + (["--smoke"] if args.fast else []))
    except Exception:
        traceback.print_exc()

    print("# === Serving: closure-index recall vs latency ===", flush=True)
    try:
        from benchmarks import serving_bench
        serving_bench.main(["--json"] + (["--smoke"] if args.fast else []))
    except Exception:
        traceback.print_exc()

    print("# === Hierarchy: flat vs divide-and-conquer ===", flush=True)
    try:
        from benchmarks import hierarchy_bench
        hierarchy_bench.main(["--json"] + (["--smoke"] if args.fast else []))
    except Exception:
        traceback.print_exc()

    print("# === Kernel roofline (fused vs split Lloyd pass) ===",
          flush=True)
    try:
        # empty argv: run.py's own CLI args must not leak into the
        # benchmark's parser; the orchestrator always emits the JSON seed
        kernels_bench.main(["--json"])
    except Exception:
        traceback.print_exc()

    print("# === LM roofline table (from dry-run artifacts) ===",
          flush=True)
    try:
        roofline.main()
    except Exception:
        traceback.print_exc()


if __name__ == "__main__":
    main()
