#!/bin/bash
# Tuned benchmark launcher: host-allocator + XLA flags that matter for the
# solver's host-loop drivers, then delegate to benchmarks/run.py.
#
#     ./scripts/run_tuned.sh [--scale 0.05] [--fast] [--backend dense] ...
#
# Everything here is additive tuning — `python -m benchmarks.run` without
# this wrapper produces the same numbers, just slower dispatch:
#
#   * tcmalloc (when installed) — glibc malloc serialises the chunk
#     pipeline's large host allocations (every host_chunk_stream gather
#     and device_get snapshot) behind a global arena lock; tcmalloc's
#     per-thread caches remove that, which matters now that the
#     checkpoint writer allocates from a second thread.
#   * --xla_cpu_multi_thread_eigen / intra-op threads — let XLA's CPU
#     backend use the host cores the container actually has.
#   * TF_CPP_MIN_LOG_LEVEL=4 silences absl chatter so the CSV output
#     stays machine-parseable.
#
# test.sh is the correctness entry point and stays untuned on purpose:
# tests must pass under the allocator/threading defaults users get.

set -euo pipefail
cd "$(dirname "$0")/.."

# tcmalloc when present (never required): check the usual soname spots
for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
          /usr/lib/libtcmalloc.so.4; do
  if [[ -e "$so" ]]; then
    export LD_PRELOAD="$so${LD_PRELOAD:+:$LD_PRELOAD}"
    # only report truly large allocations (default threshold spams the
    # log with every chunk buffer)
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=17179869184
    break
  fi
done

export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}
export XLA_FLAGS="--xla_cpu_multi_thread_eigen=true ${XLA_FLAGS:-}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m benchmarks.run "$@"
